"""Structured trace subsystem (DESIGN.md §18): conservation gates, pinned
PR-9 parity, the Figure-10 breakdown, exporters, and the serving ledger.

The load-bearing claims, each tested here:

- ``trace=False`` is byte-identical to the pre-trace engine (pinned against
  ``tests/fixtures/trace_parity_pr9.json`` on all three platforms);
- ``trace=True`` perturbs NO metered value, and the recorder satisfies the
  three conservation invariants exactly (==, not approx): spans tile each
  worker clock, the $ ledger sums to ``finalize_cost``, traced wire bytes
  equal the ``comm_bytes``/``ckpt_bytes`` meters;
- the same holds across a seeded platform x sync x codec x failure grid
  (and under hypothesis when installed -- see test_properties.py);
- the Chrome exporter emits loadable trace-event JSON via the registry.
"""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core.trace import (
    EXPORTERS, PHASES, TraceRecorder, assert_invariants, check_invariants,
    derive_breakdown, export_chrome, list_exporters, make_exporter,
    render_breakdown, render_invariants,
)
from repro.experiments import ExperimentSpec, run_experiment

FIXTURE = Path(__file__).parent / "fixtures" / "trace_parity_pr9.json"

#: valid platform x sync x codec x failure combinations (lossy codecs only
#: pair with collective-reduce syncs; spot preemption needs a restart path)
GRID = [
    {"platform": "faas", "sync": "bsp", "comm": {"codec": "fp32"}},
    {"platform": "faas", "sync": "asp"},
    {"platform": "faas", "sync": "ssp:2",
     "fleet": {"workers": 3, "straggler": 3.0}},
    {"platform": "faas", "sync": "bsp", "comm": {"codec": "int8"}},
    {"platform": "iaas", "sync": "bsp", "comm": {"codec": "topk:0.05"}},
    {"platform": "iaas", "sync": "ssp:2",
     "failure": {"inject": [[0, 30.0]], "spot": True},
     "ckpt": "s3:every=2"},
    {"platform": "iaas", "sync": "local:2"},
    {"platform": "iaas", "sync": "bsp", "scaling": "smlt:2",
     "fleet": {"workers": 4}},
    {"platform": "pod", "sync": "local:2:c8"},
    {"platform": "pod", "sync": "bsp",
     "failure": {"inject": [[0, 10.0]], "spot": True}},
]


def _spec(over: dict) -> ExperimentSpec:
    base = {"rows": 2_500, "max_epochs": 2, "seed": 3,
            "fleet": {"workers": 2},
            "algo_args": {"lr": 0.2, "batch_size": 1024}}
    base.update(over)
    return ExperimentSpec.from_dict(base)


def _run(spec: ExperimentSpec, trace: bool):
    model, algo, tr, va = spec.build_workload()
    return spec.build_runtime().train(model, algo, tr, va,
                                      max_epochs=spec.max_epochs,
                                      trace=trace)


# ----------------------------------------------------- pinned PR-9 parity ---

def _fixture_cases():
    return json.loads(FIXTURE.read_text())["cases"]


@pytest.mark.parametrize("case", _fixture_cases(),
                         ids=lambda c: c["spec"]["name"])
def test_trace_off_is_byte_identical_to_pr9(case):
    """The recorder is structurally absent when disabled: every metered
    value equals the pinned pre-trace output EXACTLY (==, full float64)."""
    spec = ExperimentSpec.from_dict(case["spec"])
    res = _run(spec, trace=False)
    exp = case["result"]
    assert res.trace is None
    assert res.system == exp["system"]
    assert res.rounds == exp["rounds"]
    assert res.sim_time == exp["sim_time"]
    assert res.cost == exp["cost"]
    assert res.comm_bytes == exp["comm_bytes"]
    assert res.comm_cost == exp["comm_cost"]
    assert res.ckpt_bytes == exp["ckpt_bytes"]
    assert res.ckpt_time == exp["ckpt_time"]
    assert res.ckpt_cost == exp["ckpt_cost"]
    assert res.preemptions == exp["preemptions"]
    assert res.max_staleness == exp["max_staleness"]
    assert res.breakdown == exp["breakdown"]
    assert [[t, l] for t, l in res.history] == exp["history"]
    assert [list(x) for x in res.scaling_timeline] == exp["scaling_timeline"]


@pytest.mark.parametrize("case", _fixture_cases(),
                         ids=lambda c: c["spec"]["name"])
def test_trace_on_perturbs_nothing_and_conserves(case):
    """trace=True: same metered outputs, plus the three gates hold."""
    spec = ExperimentSpec.from_dict(case["spec"])
    res = _run(spec, trace=True)
    exp = case["result"]
    assert res.sim_time == exp["sim_time"]
    assert res.cost == exp["cost"]
    assert res.breakdown == exp["breakdown"]
    assert [[t, l] for t, l in res.history] == exp["history"]
    inv = assert_invariants(res)
    assert inv["ok"]
    # the meter mirror is the breakdown, bitwise
    assert res.trace.meters == res.breakdown


# ------------------------------------------------------------ spec grid -----

@pytest.mark.parametrize("over", GRID,
                         ids=lambda o: f"{o['platform']}-{o['sync']}")
def test_invariants_hold_across_grid(over):
    spec = _spec(over)
    res = _run(spec, trace=True)
    assert res.error == ""
    inv = assert_invariants(res)
    assert inv["clock"]["spans"] == len(res.trace.spans)
    assert res.trace.meters == res.breakdown
    # every span cites a known phase
    assert {s.phase for s in res.trace.spans} <= set(PHASES)


def test_grid_traced_equals_untraced():
    """A seeded sample of the grid, run both ways: every metered value is
    bitwise-equal with the recorder on."""
    rng = np.random.default_rng(0)
    for k in rng.choice(len(GRID), size=4, replace=False):
        spec = _spec(GRID[int(k)])
        r0, r1 = _run(spec, trace=False), _run(spec, trace=True)
        assert r0.sim_time == r1.sim_time
        assert r0.cost == r1.cost
        assert r0.breakdown == r1.breakdown
        assert r0.comm_bytes == r1.comm_bytes
        assert r0.ckpt_bytes == r1.ckpt_bytes
        assert [l for _, l in r0.history] == [l for _, l in r1.history]


# ------------------------------------------------------------- breakdown ----

def test_breakdown_derives_from_spans_alone():
    res = _run(_spec({"platform": "faas", "sync": "bsp"}), trace=True)
    bd = derive_breakdown(res.trace)
    assert set(bd["phases"]) == set(PHASES)
    # per-phase seconds re-sum to each worker's wall clock (float tolerance:
    # the EXACT tiling claim is the invariant; this is the aggregate view)
    for wid, phases in bd["per_worker"].items():
        np.testing.assert_allclose(sum(phases.values()), bd["wall"][wid],
                                   rtol=1e-9)
    # $ ledger covers the whole bill
    assert sum(bd["usd"].values()) == pytest.approx(res.cost, rel=1e-12)
    text = render_breakdown(res.trace, title="t")
    for phase in PHASES:
        assert phase in text
    assert "[OK  ]" in render_invariants(check_invariants(res))


def test_run_record_carries_trace_section(tmp_path):
    spec = _spec({"platform": "faas", "sync": "bsp", "trace": True})
    rec = run_experiment(spec, cache_dir=tmp_path)
    d = json.loads(Path(rec.path).read_text())
    assert d["schema"] == "repro.experiment/v2"
    t = d["result"]["trace"]
    assert set(t["breakdown"]) == set(PHASES)
    assert all(t["invariants"][k] for k in ("clock", "cost", "bytes"))
    assert t["spans"] > 0                    # counts: full spans go through
    assert sum(t["usd"].values()) == pytest.approx(   # the exporter, not
        d["result"]["cost_usd"], rel=1e-12)           # the record cache
    # full-precision record vs rounded presentation (satellite: rounding
    # only happens in summary(), never in the stored record)
    res = _run(spec, trace=False)
    assert d["result"]["sim_time_s"] == res.sim_time
    assert d["result"]["cost_usd"] == res.cost
    s = res.summary()
    assert s["sim_time_s"] == round(res.sim_time, 2)
    assert s["cost_usd"] == round(res.cost, 4)


# -------------------------------------------------------------- exporters ---

def test_exporter_registry_round_trip():
    assert list_exporters() == sorted(EXPORTERS)
    for name in list_exporters():
        assert make_exporter(name) is EXPORTERS[name]
    with pytest.raises(ValueError, match="chrome"):
        make_exporter("flamegraph")


def test_chrome_export_is_valid_trace_event_json(tmp_path):
    res = _run(_spec({"platform": "iaas", "sync": "ssp:2"}), trace=True)
    doc = export_chrome(res.trace)
    path = tmp_path / "t.json"
    path.write_text(json.dumps(doc))
    loaded = json.loads(path.read_text())
    events = loaded["traceEvents"]
    assert loaded["displayTimeUnit"] == "ms"
    xs = [e for e in events if e["ph"] == "X"]
    assert len(xs) == len(res.trace.spans)
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] > 0
        assert isinstance(e["tid"], int) and e["pid"] == 0
        assert e["name"] and e["cat"] in PHASES
    # µs timestamps: span t0 in simulated seconds -> ts in microseconds
    s0 = res.trace.spans[0]
    assert any(abs(e["ts"] - s0.t0 * 1e6) < 0.5 for e in xs)
    # thread metadata names every worker timeline
    mets = [e for e in events if e["ph"] == "M"]
    assert {e["tid"] for e in mets} == {s["tid"] for s in
                                        ({"tid": x["tid"]} for x in xs)}


# ---------------------------------------------------------------- serving ---

def _serve(platform, trace, scaling=None):
    from repro.serving.sim import serve
    return serve(platform, "smollm-360m", "poisson:4", duration_s=90,
                 seed=7, reduced=True, trace=trace, scaling=scaling)


def test_serving_trace_off_unperturbed_and_ledger_conserves():
    from repro.core.runtimes import FaaSRuntime, IaaSRuntime
    for make in (lambda: FaaSRuntime(workers=4),
                 lambda: IaaSRuntime(workers=2)):
        r0, r1 = _serve(make(), False), _serve(make(), True)
        assert r0.cost == r1.cost
        assert r0.completed == r1.completed
        assert r0.latencies == r1.latencies
        assert r0.windows == r1.windows
        assert r0.breakdown() == {} and r1.breakdown()
        # invariant 2, serving form: the ledger sums to the bill exactly
        assert r1.trace.cost_total() == r1.cost
        labels = {label for label, _ in r1.trace.cost_ledger()}
        assert labels <= {"request", "replica"}


def test_serving_request_lifecycle_spans():
    from repro.core.runtimes import FaaSRuntime
    r = _serve(FaaSRuntime(workers=2), True)
    kinds = {s.kind for s in r.trace.spans}
    assert {"serve.prefill", "serve.decode"} <= kinds
    assert r.cold_starts == sum(1 for s in r.trace.spans
                                if s.kind == "serve.coldstart")
    # one ledger entry per admitted request, in admission order
    ledger = r.trace.cost_ledger()
    assert len(ledger) == len(r.per_request_usd)
    assert [usd for _, usd in ledger] == r.per_request_usd


def test_serving_provisioned_ledger_matches_replica_spans():
    from repro.core.runtimes import IaaSRuntime
    r = _serve(IaaSRuntime(workers=2), True, scaling="smlt:2")
    assert len(r.trace.cost_ledger()) == len(r.provisioned)
    assert r.trace.cost_total() == r.cost


# -------------------------------------------------------- recorder units ----

def test_recorder_drops_zero_length_spans_and_sums_sequentially():
    rec = TraceRecorder("train")
    rec.birth(0, 0.0)
    rec.span(0, "compute", "compute", 1.0, 1.0)    # zero length: dropped
    rec.span(0, "compute", "compute", 0.0, 1.0)
    assert len(rec.spans) == 1
    rec.cost("a", 0.1)
    rec.cost("b", 0.2)
    assert rec.cost_total() == (0.0 + 0.1) + 0.2   # left-assoc, from 0.0
    rec.cost_reset()
    assert rec.cost_total() == 0.0
    rec.bytes_event("comm", 7)
    rec.bytes_event("comm", 5)
    assert rec.bytes_total("comm") == 12.0
    assert rec.bytes_total("ckpt") == 0.0
