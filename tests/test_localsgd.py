"""LocalSGD / DiLoCo sync protocol (DESIGN.md §11): H=1 is bit-identical to
BSP on all three infrastructures, metered comm bytes shrink exactly 1/H
(/4 more with int8 deltas), the outer math is shared with the real pod
stack, and non-additive algorithms are rejected."""
import numpy as np
import pytest

from repro.core.algorithms import make_algorithm
from repro.core.mlmodels import make_study_model
from repro.core.runtimes import FaaSRuntime, IaaSRuntime, PodPlatform
from repro.core.sync import (
    BSP, DiLoCoOuter, LocalSGD, dequantize_int8, int8_wire_floats, make_sync,
    quantize_int8_ef, sync_name,
)
from repro.data.synthetic import make_dataset, train_val_split


@pytest.fixture(scope="module")
def higgs():
    ds = make_dataset("higgs", rows=6_000)
    return train_val_split(ds)


def _ga(**kw):
    return make_algorithm("ga_sgd", **{"lr": 0.2, "batch_size": 512, **kw})


PLATFORMS = {
    "faas": lambda sync: FaaSRuntime(workers=3, sync=sync),
    "iaas": lambda sync: IaaSRuntime(workers=3, sync=sync),
    "pod": lambda sync: PodPlatform(pods=3, sync=sync),
}


# ------------------------------------------------------------ spec parsing --

def test_sync_spec_parses_and_round_trips():
    p = make_sync("local:4")
    assert isinstance(p, LocalSGD) and p.h == 4 and not p.compress
    assert p.outer == "ma"
    d = make_sync("diloco:2:c8")
    assert d.outer == "diloco" and d.h == 2 and d.compress
    assert make_sync("local").h == 8
    assert make_sync("local:c8").compress          # default H, compressed
    for s in ("local:1", "local:8:c8", "diloco:8", "diloco:3:c8"):
        assert sync_name(s) == s
    assert sync_name("local") == "local:8"
    assert sync_name(LocalSGD(h=5, outer="diloco")) == "diloco:5"
    with pytest.raises(KeyError):
        make_sync("local:8:zstd")
    with pytest.raises(ValueError):
        LocalSGD(outer="fedavg")
    with pytest.raises(ValueError, match="H must be >= 1"):
        make_sync("local:0")
    # custom DiLoCo outer hyperparameters cannot round-trip through a spec
    # string -- refuse to serialize rather than silently drop them
    with pytest.raises(ValueError, match="outer_lr"):
        sync_name(LocalSGD(h=2, outer="diloco", outer_lr=0.1))
    # (MA ignores the outer optimizer, so it serializes fine)
    assert sync_name(LocalSGD(h=2, outer="ma", outer_lr=0.1)) == "local:2"


# ------------------------------------------------------- H=1 == BSP parity --

@pytest.mark.parametrize("plat", sorted(PLATFORMS), ids=sorted(PLATFORMS))
def test_local_h1_bit_identical_to_bsp(higgs, plat):
    """Protocol parity: LocalSGD(H=1) degenerates to exactly one
    bsp_reduce + apply per round -- same losses, same simulated times,
    same metered bytes/cost, on every platform."""
    tr, va = higgs
    model = make_study_model("lr", tr)
    rb = PLATFORMS[plat]("bsp").train(model, _ga(), tr, va, max_epochs=2)
    rl = PLATFORMS[plat]("local:1").train(model, _ga(), tr, va, max_epochs=2)
    assert rb.history == rl.history            # losses AND times, bit-exact
    assert rb.comm_bytes == rl.comm_bytes
    assert rb.cost == rl.cost
    assert rb.rounds == rl.rounds


# ----------------------------------------------------------- byte metering --

def _expected_syncs(rounds: int, h: int) -> int:
    return sum(1 for rnd in range(rounds)
               if (rnd + 1) % h == 0 or rnd == rounds - 1)


@pytest.mark.parametrize("h", [1, 2, 4])
def test_metered_bytes_shrink_exactly_by_h(higgs, h):
    tr, va = higgs
    model = make_study_model("lr", tr)
    vec_bytes = tr.d * 4                       # flat fp32 update vector
    res = PodPlatform(pods=3, sync=f"local:{h}").train(
        model, _ga(), tr, va, max_epochs=4)
    assert res.comm_bytes == _expected_syncs(res.rounds, h) * vec_bytes


def test_compressed_wire_bytes_are_quarter(higgs):
    tr, va = higgs
    model = make_study_model("lr", tr)
    res = PodPlatform(pods=3, sync="local:2:c8").train(
        model, _ga(), tr, va, max_epochs=4)
    wire = int8_wire_floats(tr.d) * 4          # packed codes + one scale
    assert res.comm_bytes == _expected_syncs(res.rounds, 2) * wire
    assert wire <= tr.d * 4 / 4 + 4            # /4 (+ the 4-byte scale)


def test_asp_and_bsp_meter_the_same_total_bytes(higgs):
    """Cross-protocol accounting symmetry: every protocol ships one update
    vector per per-worker round, so for the same epochs ASP's total
    comm_bytes equals BSP's (w x the worker-rounds, 1/w the per-event
    payload share)."""
    tr, va = higgs
    model = make_study_model("lr", tr)
    rb = IaaSRuntime(workers=3, sync="bsp").train(model, _ga(), tr, va,
                                                  max_epochs=2)
    ra = IaaSRuntime(workers=3, sync="asp").train(model, _ga(), tr, va,
                                                  max_epochs=2)
    assert ra.rounds == rb.rounds * 3
    np.testing.assert_allclose(ra.comm_bytes, rb.comm_bytes, rtol=1e-12)


def test_comm_seconds_shrink_with_h(higgs):
    tr, va = higgs
    model = make_study_model("lr", tr)
    secs = {}
    for sync in ("bsp", "local:8"):
        res = PodPlatform(pods=3, sync=sync).train(model, _ga(), tr, va,
                                                   max_epochs=4)
        secs[sync] = res.breakdown["comm"]
    assert secs["local:8"] * 4 <= secs["bsp"]


# ------------------------------------------------------------- shared math --

def test_quantizer_error_feedback_identity():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((5, 257)).astype(np.float32) * 3.0
    q, scale, err = quantize_int8_ef(x)
    assert np.asarray(q).dtype == np.int8
    np.testing.assert_allclose(
        np.asarray(dequantize_int8(q, scale)) + np.asarray(err), x,
        rtol=1e-6, atol=1e-6)
    # error is bounded by half a quantization step per channel
    assert np.all(np.abs(np.asarray(err)) <= np.asarray(scale) * 0.5 + 1e-7)


def test_diloco_outer_matches_nesterov_formula():
    opt = DiLoCoOuter(lr=0.7, momentum=0.9)
    outer = np.ones(4, np.float32)
    mom = np.full(4, 0.5, np.float32)
    delta = np.full(4, 0.1, np.float32)
    new_outer, new_mom = opt.step(outer, mom, delta)
    want_mom = 0.9 * mom + delta
    np.testing.assert_allclose(new_mom, want_mom)
    np.testing.assert_allclose(new_outer,
                               outer - 0.7 * (0.9 * want_mom + delta))


def test_diloco_converges_and_pods_agree(higgs):
    tr, va = higgs
    model = make_study_model("lr", tr)
    res = PodPlatform(pods=3, sync="diloco:4").train(model, _ga(), tr, va,
                                                     max_epochs=4)
    assert not res.error
    assert res.history[-1][1] < res.history[0][1]
    # determinism of the outer path: a second run reproduces the history
    # exactly (eval reads worker 0, which every outer step overwrites)
    res2 = PodPlatform(pods=3, sync="diloco:4").train(model, _ga(), tr, va,
                                                      max_epochs=4)
    assert res.history == res2.history


def test_target_loss_checked_at_every_boundary(higgs):
    """eval_every must never disable convergence checks for H > 1: the
    averaging boundaries land on odd round indices (k*H-1), so LocalSGD
    evaluates at every boundary regardless of eval_every phase."""
    tr, va = higgs
    model = make_study_model("lr", tr)
    res = PodPlatform(pods=3, sync="local:8").train(
        model, _ga(), tr, va, max_epochs=16, eval_every=2, target_loss=0.5)
    assert res.converged
    assert len(res.history) >= 1 and res.history[-1][1] <= 0.5
    assert res.rounds < 16 * 2       # stopped well before max_epochs


# ------------------------------------------------------------------ guards --

def test_non_additive_algorithms_rejected(higgs):
    tr, va = higgs
    model = make_study_model("lr", tr)
    algo = make_algorithm("ma_sgd", lr=0.1, batch_size=512)
    with pytest.raises(ValueError, match="additive"):
        PodPlatform(pods=2, sync="local:4").train(model, algo, tr, va,
                                                  max_epochs=1)
