"""The composable communication API (DESIGN.md §12): Transport x
Collective x Codec parity with the seed-era paths, the string grammar,
spec-time validation (DynamoDB 400 KB -> Table 1 "N/A" as an eager error),
exact codec byte metering on all three platforms, and the new hierarchical
/ top-k members of the design space."""
import numpy as np
import pytest

from repro.core.algorithms import make_algorithm
from repro.core.comm import (
    ChannelItemTooLarge, CommStack, StorageChannel, Transport, VMNetwork,
    VMParameterServer, allreduce, make_codec, make_collective,
    parse_stack, scatter_reduce, two_level_reduce,
)
from repro.core.mlmodels import make_study_model
from repro.core.platform import CommSpec
from repro.core.runtimes import FaaSRuntime, IaaSRuntime, PodPlatform
from repro.data.synthetic import make_dataset, train_val_split
from repro.experiments import ExperimentSpec, run_experiment


@pytest.fixture(scope="module")
def higgs():
    ds = make_dataset("higgs", rows=6_000)
    return train_val_split(ds)


def _ga(**kw):
    return make_algorithm("ga_sgd", **{"lr": 0.2, "batch_size": 512, **kw})


class _Ctx:
    """Minimal metering surface a CommStack drives (duck-typed SimContext)."""

    def __init__(self, w):
        self.clock = np.zeros(w)
        self.breakdown = {}
        self.bytes = 0.0
        self.rec = None
        self.worker_ids = list(range(w))

    def meter_add(self, key, dt):
        self.breakdown[key] = self.breakdown.get(key, 0.0) + dt

    def meter_bytes(self, n):
        self.bytes += n


# ------------------------------------------------------------- the grammar --

def test_parse_stack_grammar():
    assert parse_stack("s3/scatter_reduce/int8") == (
        "s3", "scatter_reduce", "int8")
    assert parse_stack("s3") == ("s3", None, "fp32")
    assert parse_stack("dcn/ring") == ("dcn", "ring", "fp32")
    with pytest.raises(KeyError):
        parse_stack("carrier_pigeon/allreduce/fp32")
    with pytest.raises(KeyError):
        parse_stack("s3/gossip/fp32")
    with pytest.raises(KeyError):
        parse_stack("s3/allreduce/zstd")
    with pytest.raises(ValueError):
        parse_stack("s3/allreduce/fp32/extra")
    with pytest.raises(ValueError):
        parse_stack("s3//fp32")
    with pytest.raises(ValueError):
        make_codec("topk:1.5")              # fraction out of range
    with pytest.raises(ValueError):
        make_collective("hierarchical:0")   # group size must be >= 1


def test_comm_spec_parse_and_resolution():
    c = CommSpec.parse("memcached/scatter_reduce/int8")
    assert c.channel == "memcached"           # legacy view mirrors
    assert c.pattern == "scatter_reduce"
    assert c.resolved("faas") == ("memcached", "scatter_reduce", "int8")
    # platform defaults: untouched CommSpec keeps the seed-era behavior
    d = CommSpec()
    assert d.resolved("faas") == ("s3", "allreduce", "fp32")
    assert d.resolved("iaas") == ("nic", "ring", "fp32")
    assert d.resolved("pod") == ("dcn", "ring", "fp32")
    assert CommSpec(channel="vmps").resolved("faas") == (
        "vmps", "pushpull", "fp32")
    # explicit transports pin the stack on any platform
    e = CommSpec.parse("s3/hierarchical:4/topk:0.02")
    assert e.resolved("iaas") == ("s3", "hierarchical:4", "topk:0.02")
    assert e.stack_name("iaas") == "s3/hierarchical:4/topk:0.02"
    with pytest.raises(KeyError):
        CommSpec(channel="floppynet")


def test_pairing_and_platform_rules():
    with pytest.raises(ValueError, match="ring"):
        CommSpec.parse("s3/ring/fp32").validate(platform="faas")
    with pytest.raises(ValueError, match="push/pull"):
        CommSpec.parse("vmps/allreduce/fp32").validate(platform="faas")
    with pytest.raises(ValueError, match="push/pull"):
        CommSpec.parse("s3/pushpull/fp32").validate(platform="faas")
    with pytest.raises(ValueError, match="FaaS"):
        CommSpec.parse("nic/ring/fp32").validate(platform="faas")
    # ...but the same stack is the IaaS default, and spec-level too
    CommSpec.parse("nic/ring/int8").validate(platform="iaas")
    with pytest.raises(ValueError, match="FaaS"):
        ExperimentSpec(comm="nic/ring/fp32")
    assert ExperimentSpec(platform="iaas",
                          comm="nic/ring/int8").comm.codec == "int8"


def test_transports_satisfy_protocol():
    for t in (StorageChannel("s3"), VMNetwork(120e6, 5e-4),
              VMParameterServer()):
        assert isinstance(t, Transport)
        dt = t.put("k", np.ones(64, np.float32))
        _, dt2 = t.get("k")
        assert dt >= 0 and dt2 >= 0
        assert t.service_cost(10.0) >= 0.0
        assert t.spec.bandwidth > 0


# ---------------------------------------------------- seed-path parity ------

@pytest.mark.parametrize("pattern", ["allreduce", "scatter_reduce"])
def test_stack_string_byte_identical_to_legacy(higgs, pattern):
    """`s3/<pattern>/fp32` IS the legacy patterns.* path: same losses,
    same clocks, same bytes, same dollars."""
    tr, va = higgs
    model = make_study_model("lr", tr)
    legacy = FaaSRuntime(workers=4, pattern=pattern).train(
        model, _ga(), tr, va, max_epochs=2)
    stack = FaaSRuntime(workers=4, comm=f"s3/{pattern}/fp32").train(
        model, _ga(), tr, va, max_epochs=2)
    assert legacy.history == stack.history       # bit-exact, times included
    assert legacy.sim_time == stack.sim_time
    assert legacy.cost == stack.cost
    assert legacy.comm_bytes == stack.comm_bytes
    assert legacy.comm_cost == stack.comm_cost


def test_experiment_spec_string_comm_parity(higgs):
    """Acceptance: ExperimentSpec(comm="s3/allreduce/fp32") reproduces the
    legacy (default CommSpec) channel path byte-identically."""
    base = ExperimentSpec(model="lr", rows=4_000, max_epochs=2,
                          algorithm="ga_sgd",
                          algo_args={"lr": 0.2, "batch_size": 512})
    rec_default = run_experiment(base, cache_dir=None)
    rec_string = run_experiment(base.with_(comm="s3/allreduce/fp32"),
                                cache_dir=None)
    assert rec_default.result == rec_string.result


def test_vmps_and_ring_legacy_parity(higgs):
    tr, va = higgs
    model = make_study_model("lr", tr)
    old = FaaSRuntime(workers=3, channel="vmps").train(
        model, _ga(), tr, va, max_epochs=2)
    new = FaaSRuntime(workers=3, comm="vmps/pushpull/fp32").train(
        model, _ga(), tr, va, max_epochs=2)
    assert old.history == new.history and old.cost == new.cost
    old_i = IaaSRuntime(workers=3).train(model, _ga(), tr, va, max_epochs=2)
    new_i = IaaSRuntime(workers=3, comm="nic/ring/fp32").train(
        model, _ga(), tr, va, max_epochs=2)
    assert old_i.history == new_i.history and old_i.cost == new_i.cost


def test_stack_reduce_matches_raw_pattern_functions():
    """CommStack drives the SAME free functions patterns.py always
    exported -- merged vector and per-worker times agree exactly."""
    rng = np.random.default_rng(0)
    ups = [rng.standard_normal(500).astype(np.float32) for _ in range(5)]
    for name, fn in [("allreduce", allreduce),
                     ("scatter_reduce", scatter_reduce),
                     ("hierarchical", two_level_reduce)]:
        want_m, want_t = fn(StorageChannel("s3"), [u.copy() for u in ups],
                            "ref")
        ctx = _Ctx(5)
        stack = CommStack(StorageChannel("s3"), name)
        got_m = stack.bsp_reduce(ctx, [u.copy() for u in ups], "ref")
        np.testing.assert_array_equal(want_m, got_m)
        np.testing.assert_array_equal(np.asarray(want_t, float), ctx.clock)
        assert ctx.bytes == ups[0].nbytes


# ------------------------------------------------------------- collectives --

def test_hierarchical_reduces_to_the_mean_and_scales():
    rng = np.random.default_rng(1)
    w, n = 16, 2_000_000
    ups = [rng.standard_normal(n).astype(np.float32) for _ in range(w)]
    want = np.mean(ups, axis=0)
    m, t = two_level_reduce(StorageChannel("s3"), ups, "h")
    np.testing.assert_allclose(m, want, rtol=1e-5, atol=1e-6)
    assert np.all(np.asarray(t) > 0) and len(t) == w
    # FSD-Inference scaling: the two-level tree flattens AllReduce's
    # leader bottleneck (leader touches g + w/g objects, not w)
    _, t_ar = allreduce(StorageChannel("s3"), ups, "a")
    assert float(np.max(t)) < float(np.max(t_ar))
    # explicit group size round-trips through the grammar
    m4, _ = two_level_reduce(StorageChannel("s3"), ups[:8], "g", 4)
    np.testing.assert_allclose(m4, np.mean(ups[:8], axis=0),
                               rtol=1e-5, atol=1e-6)


def test_collective_item_sizes():
    ar = make_collective("allreduce")
    sr = make_collective("scatter_reduce")
    ring = make_collective("ring")
    assert ar.max_item_bytes(12_000_000, 8) == 12_000_000
    assert sr.max_item_bytes(12_000_000, 8) == 1_500_000
    assert ring.max_item_bytes(12_000_000, 8) == 0


# ------------------------------------------------------------------ codecs --

def test_codec_error_feedback_units():
    int8 = make_codec("int8")
    v = np.linspace(-1.0, 1.0, 97).astype(np.float32)
    deq = int8.encode_decode(0, v)
    # round trip + carried residual reconstructs the input exactly
    np.testing.assert_allclose(deq + int8._residual[0], v,
                               rtol=1e-6, atol=1e-7)
    topk = make_codec("topk:0.1")
    out = topk.encode_decode(0, v)
    assert np.count_nonzero(out) == topk._k(v.size)
    np.testing.assert_allclose(out + topk._residual[0], v,
                               rtol=1e-6, atol=1e-7)
    # the filtered mass is deferred, not lost: a second round ships it
    out2 = topk.encode_decode(0, np.zeros_like(v))
    assert np.count_nonzero(out2) > 0
    assert make_codec("topk:1").encode_decode(1, v) is not None
    assert make_codec("fp32").encode_decode(0, v) is v


@pytest.mark.parametrize("plat", ["faas", "iaas", "pod"])
def test_codec_shrinks_comm_bytes_exactly(higgs, plat):
    """Acceptance: .../int8 and .../topk shrink metered comm_bytes by
    exactly the codec's wire ratio on all three platforms."""
    tr, va = higgs
    model = make_study_model("lr", tr)
    builders = {
        "faas": lambda c: FaaSRuntime(workers=3, comm=f"s3/allreduce/{c}"),
        "iaas": lambda c: IaaSRuntime(workers=3, comm=f"nic/ring/{c}"),
        "pod": lambda c: PodPlatform(pods=3, comm=f"dcn/ring/{c}"),
    }
    n = 28                                   # lr/higgs update elements
    base = builders[plat]("fp32").train(model, _ga(), tr, va, max_epochs=2)
    assert base.comm_bytes > 0
    for codec in ("int8", "topk:0.25"):
        r = builders[plat](codec).train(model, _ga(), tr, va, max_epochs=2)
        wf = make_codec(codec).wire_floats(n)
        # exact ratio via integer cross-multiplication (no float division)
        assert int(r.comm_bytes) * n == int(base.comm_bytes) * wf
        assert np.isfinite(r.final_loss) and r.rounds == base.rounds


def test_int8_comm_bytes_blockwise_engine_level():
    """Acceptance (DESIGN.md §16): an "s3/allreduce/int8" run's metered
    comm_bytes follow the BLOCKWISE wire formula exactly -- ceil(n/4)
    packed-code floats plus ceil(n/256) per-block fp32 scales per round.
    mobilenet-sized so the per-block term actually differs from the old
    per-vector-scale accounting (n >> 256)."""
    from repro.core.comm.codecs import QUANT_BLOCK, int8_wire_floats
    from repro.core.workloads import estimate_update_bytes

    ds = make_dataset("cifar10", rows=600)
    tr, va = train_val_split(ds)
    mn = make_study_model("mobilenet", tr)
    n = estimate_update_bytes("mobilenet", "cifar10") // 4
    assert n > QUANT_BLOCK
    r = FaaSRuntime(workers=2, comm="s3/allreduce/int8").train(
        mn, make_algorithm("ga_sgd", lr=0.05, batch_size=512), tr, va,
        max_epochs=1)
    want = -(-n // 4) + -(-n // QUANT_BLOCK)
    assert want == int8_wire_floats(n)
    assert r.rounds > 0 and int(r.comm_bytes) == r.rounds * want * 4


# ------------------------------------------------- spec-time validation -----

def test_dynamodb_na_is_an_eager_spec_error():
    """Acceptance: "dynamodb/..." with a > 400 KB model fails at spec
    construction, naming the model size and the channel limit."""
    with pytest.raises(ChannelItemTooLarge) as ei:
        ExperimentSpec(comm="dynamodb/allreduce/fp32", model="mobilenet",
                       dataset="cifar10")
    msg = str(ei.value)
    assert "dynamodb" in msg and "400" in msg and "MB" in msg
    # a small model fits fine
    ExperimentSpec(comm="dynamodb/allreduce/fp32", model="lr")
    # MLLess's point: sparsifying the update flips the cell to feasible
    ExperimentSpec(comm="dynamodb/allreduce/topk:0.001", model="mobilenet",
                   dataset="cifar10")
    # ...and so does scatter-reduce + int8 (375 KB items at w=8)
    from repro.experiments.spec import FleetSpec
    ExperimentSpec(comm="dynamodb/scatter_reduce/int8", model="mobilenet",
                   dataset="cifar10", fleet=FleetSpec(workers=8))
    with pytest.raises(ChannelItemTooLarge):
        ExperimentSpec(comm="dynamodb/scatter_reduce/fp32",
                       model="mobilenet", dataset="cifar10",
                       fleet=FleetSpec(workers=8))


def test_runtime_validate_reports_item_limit(higgs):
    """Direct FaaSRuntime use fails at validate() (error result, no
    mid-simulation crash), keeping the bench_channels N/A convention."""
    ds = make_dataset("cifar10", rows=600)
    tr, va = train_val_split(ds)
    mn = make_study_model("mobilenet", tr)
    r = FaaSRuntime(workers=4, channel="dynamodb").train(
        mn, make_algorithm("ga_sgd", lr=0.05, batch_size=512), tr, va,
        max_epochs=1)
    assert "dynamodb" in r.error and not r.history


def test_lossy_codec_rejected_under_asp_ssp(higgs):
    """A lossy codec would be a silent no-op in the ASP/SSP global-model
    loop -- rejected at spec time AND at direct runtime use."""
    with pytest.raises(ValueError, match="no effect"):
        ExperimentSpec(sync="asp", comm="s3/allreduce/int8")
    with pytest.raises(ValueError, match="no effect"):
        ExperimentSpec(sync="ssp:2", comm="s3/allreduce/topk:0.1")
    ExperimentSpec(sync="asp", comm="s3/allreduce/fp32")    # identity is fine
    ExperimentSpec(sync="local:4", comm="s3/allreduce/int8")
    tr, va = higgs
    model = make_study_model("lr", tr)
    with pytest.raises(ValueError, match="no effect"):
        FaaSRuntime(workers=3, sync="asp", comm="s3/allreduce/int8").train(
            model, _ga(), tr, va, max_epochs=1)


def test_storage_stack_on_iaas_bills_and_provisions(higgs):
    """A storage/PS stack pinned on IaaS pays the service's startup and
    dollars exactly as it would on FaaS (no free Memcached on VMs)."""
    tr, va = higgs
    model = make_study_model("lr", tr)
    nic = IaaSRuntime(workers=3).train(model, _ga(), tr, va, max_epochs=2)
    mc = IaaSRuntime(workers=3, comm="memcached/allreduce/fp32").train(
        model, _ga(), tr, va, max_epochs=2)
    assert mc.comm_cost > 0 and nic.comm_cost == 0.0
    # total cost includes the substrate: strictly more than VM hours + ckpt
    from repro.core import cost as pricing
    vm_hours = 3 * pricing.EC2_HOURLY["t2.medium"] / 3600.0 * mc.sim_time
    assert mc.cost >= vm_hours + mc.comm_cost
    assert mc.breakdown["startup"] >= 130.0      # ElastiCache provisioning
    assert np.isfinite(mc.final_loss)


def test_comm_spec_json_round_trip():
    spec = ExperimentSpec(comm="s3/hierarchical:4/topk:0.02", model="lr")
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec and again.spec_hash() == spec.spec_hash()
    assert again.comm.resolved("faas") == ("s3", "hierarchical:4",
                                           "topk:0.02")
    # string comm in a sweep override expands like any other field
    assert spec.with_(comm="s3/allreduce/fp32").comm == CommSpec.parse(
        "s3/allreduce/fp32")


def test_cli_list_prints_comm_registries(capsys):
    from repro.__main__ import main
    assert main(["list"]) == 0
    out = capsys.readouterr().out
    assert "transports:" in out and "collectives:" in out
    assert "codecs:" in out and "hierarchical" in out and "topk" in out


# ------------------------------------------ hypothesis byte-scaling law -----

def test_comm_bytes_scale_exactly_with_codec_property():
    pytest.importorskip("hypothesis", reason="optional test dependency")
    from hypothesis import given, settings, strategies as st

    @given(w=st.integers(2, 10), n=st.integers(8, 3_000),
           frac=st.floats(0.001, 1.0),
           collective=st.sampled_from(["allreduce", "scatter_reduce",
                                       "hierarchical"]))
    @settings(max_examples=40, deadline=None)
    def prop(w, n, frac, collective):
        rng = np.random.default_rng(n * w)
        ups = [rng.standard_normal(n).astype(np.float32) for _ in range(w)]
        base = _Ctx(w)
        CommStack(StorageChannel("s3"), collective, "fp32").bsp_reduce(
            base, ups, "t")
        assert base.bytes == n * 4
        for codec in ("int8", f"topk:{frac}"):
            c = make_codec(codec)
            ctx = _Ctx(w)
            CommStack(StorageChannel("s3"), collective, codec).bsp_reduce(
                ctx, ups, "t")
            # metered bytes == fp32 bytes * wire ratio, exactly (integer
            # cross-multiplication; holds for EVERY worker count)
            assert int(ctx.bytes) * n == int(base.bytes) * c.wire_floats(n)

    prop()


def test_kernel_backed_codec_matches_ref_backend_bitwise_property():
    """The Int8EF codec's default (Pallas interpret) backend and the
    straight-line ref oracle are bit-identical on block-aligned shapes --
    no numpy duplicate of the quantizer math survives outside ref.py."""
    pytest.importorskip("hypothesis", reason="optional test dependency")
    from hypothesis import given, settings, strategies as st

    from repro.kernels.quant8.ops import int8_roundtrip

    @given(blocks=st.integers(1, 8), seed=st.integers(0, 2 ** 16))
    @settings(max_examples=25, deadline=None)
    def prop(blocks, seed):
        rng = np.random.default_rng(seed)
        x = rng.standard_normal(blocks * 256).astype(np.float32)
        int8 = make_codec("int8")
        deq = int8.encode_decode(0, x)          # default: kernel backend
        _q, _s, dr, er = int8_roundtrip(x, backend="ref")
        assert np.array_equal(deq, np.asarray(dr))
        assert np.array_equal(int8._residual[0], np.asarray(er))

    prop()
